"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal:
pytest asserts kernel == ref across shapes and inputs; hypothesis sweeps
the space)."""

import jax
import jax.numpy as jnp


def bank_scan_ref(bank, row, lat_hit, lat_miss, lat_conflict, num_banks=64):
    """Sequential-scan reference of the bank-state timing model."""

    def step(state, br):
        b, r = br
        prev = state[b]
        lat = jnp.where(
            prev == r,
            jnp.int32(lat_hit),
            jnp.where(prev < 0, jnp.int32(lat_miss), jnp.int32(lat_conflict)),
        )
        return state.at[b].set(r), lat

    init = jnp.full((num_banks,), -1, jnp.int32)
    _, lats = jax.lax.scan(step, init, (bank, row))
    return lats


def gather_contrib_ref(src, ranks, inv_deg):
    return ranks[src] * inv_deg[src]


def gups_update_ref(table, idx, val):
    return table.at[idx].add(val)


def pagerank_step_ref(ranks, src, dst, inv_deg, damping=0.85):
    """One full PageRank iteration (dangling mass ignored: synthetic
    graphs in the examples have no dangling nodes)."""
    n = ranks.shape[0]
    contrib = gather_contrib_ref(src, ranks, inv_deg)
    gathered = jax.ops.segment_sum(contrib, dst, num_segments=n)
    return (1.0 - damping) / n + damping * gathered
