"""L1 Pallas kernel: DRAM bank-state scan (the analytic timing model).

Given a trace chunk of (flat_bank, row) pairs in program order, classify
each access against the open-row state of its bank — row hit / row miss
(closed bank) / row conflict (other row open) — and emit its latency
contribution. This is the compute hot-spot of the coordinator's fast
path: wide parameter sweeps (paper Figure 15) run the analytic model over
trace chunks instead of the cycle-accurate Rust simulator, which serves
as the oracle it is validated against.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the per-bank open-row
vector is the only sequential carry. It lives in a VMEM scratch buffer
that persists across sequential grid steps; each grid step streams one
trace block HBM→VMEM via BlockSpec and walks it with a fori_loop. The
classification arithmetic is vectorizable; the carry is tiny (NUM_BANKS
lanes). `interpret=True` everywhere — the CPU PJRT plugin cannot execute
Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Number of logical banks tracked (2 ranks x 8 banks x 4 channels of
# headroom; Rust passes flat bank ids modulo this).
NUM_BANKS = 64

# Default block size per grid step.
BLOCK = 1024


def _kernel(bank_ref, row_ref, lat_ref, state_ref, *, lat_hit, lat_miss, lat_conflict):
    """One grid step: scan BLOCK accesses, carrying per-bank open rows."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        state_ref[...] = jnp.full((NUM_BANKS,), -1, jnp.int32)

    def body(i, _):
        b = bank_ref[i]
        r = row_ref[i]
        prev = state_ref[b]
        lat = jnp.where(
            prev == r,
            jnp.int32(lat_hit),
            jnp.where(prev < 0, jnp.int32(lat_miss), jnp.int32(lat_conflict)),
        )
        lat_ref[i] = lat
        state_ref[b] = r
        return 0

    jax.lax.fori_loop(0, bank_ref.shape[0], body, 0)


def bank_scan(bank, row, lat_hit, lat_miss, lat_conflict, block=BLOCK):
    """Per-access latency classification.

    Args:
      bank: int32[N] flat bank ids in [0, NUM_BANKS).
      row: int32[N] row addresses (-1 never used).
      lat_hit/lat_miss/lat_conflict: python ints (latencies in ns or any
        consistent unit; compiled in as constants).
      block: trace block per grid step (N must be a multiple).

    Returns:
      int32[N] per-access latency.
    """
    n = bank.shape[0]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = n // block
    kernel = functools.partial(
        _kernel, lat_hit=lat_hit, lat_miss=lat_miss, lat_conflict=lat_conflict
    )
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        # The bank-state carry: persists across sequential grid steps.
        scratch_shapes=[pltpu.VMEM((NUM_BANKS,), jnp.int32)],
        interpret=True,
    )(bank, row)
