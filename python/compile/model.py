"""L2 JAX models — the compute graphs the Rust coordinator executes via
PJRT. Each calls the L1 Pallas kernels; `aot.py` lowers them once to HLO
text, and they never run under Python at simulation/serving time.

Models:

* :func:`trace_latency_model` — the analytic DRAM timing model over one
  trace chunk (bank/row streams → per-access latency + summary). Backs
  the coordinator's fast path for wide sweeps (paper Figure 15).
* :func:`pagerank_step` — one PageRank iteration over a fixed-shape CSR
  (COO) graph; the end-to-end example's inner loop.
* :func:`gups_chunk` — a GUPS update chunk over a table tile.
"""

import jax
import jax.numpy as jnp

from .kernels import bank_scan as bank_scan_mod
from .kernels import gather_update as gu

# Fixed AOT shapes (the PJRT path compiles one executable per shape).
TRACE_CHUNK = 16_384
PAGERANK_NODES = 4_096
PAGERANK_EDGES = 32_768
GUPS_TABLE = 65_536
GUPS_CHUNK = 4_096

# DDR3-1600 latency classes in nanoseconds (TimingParams::ddr3_1600):
# hit = tCCD, miss = tRCD + tRL, conflict = tRTP + tRP + tRCD + tRL.
LAT_HIT_NS = 5
LAT_MISS_NS = 28
LAT_CONFLICT_NS = 49


def trace_latency_model(bank, row):
    """Per-access latency + summary statistics for one trace chunk.

    Args:
      bank: int32[TRACE_CHUNK] flat bank ids (mod NUM_BANKS).
      row: int32[TRACE_CHUNK] row ids (>= 0).

    Returns:
      (lat int32[N], total_ns int32[1], hits int32[1], conflicts int32[1])
    """
    lat = bank_scan_mod.bank_scan(
        bank % bank_scan_mod.NUM_BANKS,
        row,
        LAT_HIT_NS,
        LAT_MISS_NS,
        LAT_CONFLICT_NS,
    )
    total = jnp.sum(lat, dtype=jnp.int32).reshape((1,))
    hits = jnp.sum(lat == LAT_HIT_NS, dtype=jnp.int32).reshape((1,))
    conflicts = jnp.sum(lat == LAT_CONFLICT_NS, dtype=jnp.int32).reshape((1,))
    return lat, total, hits, conflicts


def pagerank_step(ranks, src, dst, inv_deg):
    """One damping-0.85 PageRank iteration (gather via Pallas, scatter
    via XLA segment-sum — see gather_update.py)."""
    n = ranks.shape[0]
    contrib = gu.gather_contrib(src, ranks, inv_deg)
    gathered = jax.ops.segment_sum(contrib, dst, num_segments=n)
    return ((1.0 - 0.85) / n + 0.85 * gathered,)


def gups_chunk(table, idx, val):
    """Apply one chunk of GUPS updates to a table tile."""
    return (gu.gups_update(table, idx, val),)


def trace_latency_entry(bank, row):
    """Tuple-returning wrapper for AOT export."""
    return trace_latency_model(bank, row)
