"""L2 model tests: shapes, semantics, and AOT lowering health."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestTraceLatency:
    def test_shapes_and_summary_consistency(self):
        rng = np.random.default_rng(3)
        bank = jnp.asarray(rng.integers(0, 64, model.TRACE_CHUNK).astype(np.int32))
        row = jnp.asarray(rng.integers(0, 256, model.TRACE_CHUNK).astype(np.int32))
        lat, total, hits, conflicts = model.trace_latency_model(bank, row)
        assert lat.shape == (model.TRACE_CHUNK,)
        assert int(total[0]) == int(jnp.sum(lat))
        assert int(hits[0]) == int(jnp.sum(lat == model.LAT_HIT_NS))
        assert int(conflicts[0]) == int(jnp.sum(lat == model.LAT_CONFLICT_NS))

    def test_sequential_trace_mostly_hits(self):
        # Stream within one row of one bank: all hits after the opener.
        bank = jnp.zeros((model.TRACE_CHUNK,), jnp.int32)
        row = jnp.zeros((model.TRACE_CHUNK,), jnp.int32)
        _, _, hits, conflicts = model.trace_latency_model(bank, row)
        assert int(hits[0]) == model.TRACE_CHUNK - 1
        assert int(conflicts[0]) == 0


class TestPageRank:
    def _graph(self, seed=4):
        rng = np.random.default_rng(seed)
        n, e = model.PAGERANK_NODES, model.PAGERANK_EDGES
        src = rng.integers(0, n, e).astype(np.int32)
        dst = rng.integers(0, n, e).astype(np.int32)
        deg = np.bincount(src, minlength=n).astype(np.float32)
        inv_deg = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0).astype(np.float32)
        ranks = np.full(n, 1.0 / n, np.float32)
        return map(jnp.asarray, (ranks, src, dst, inv_deg))

    def test_matches_ref(self):
        ranks, src, dst, inv_deg = self._graph()
        (got,) = model.pagerank_step(ranks, src, dst, inv_deg)
        want = ref.pagerank_step_ref(ranks, src, dst, inv_deg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_iteration_contracts(self):
        # Repeated application converges (sum of |delta| shrinks).
        ranks, src, dst, inv_deg = self._graph()
        r1 = model.pagerank_step(ranks, src, dst, inv_deg)[0]
        r2 = model.pagerank_step(r1, src, dst, inv_deg)[0]
        r3 = model.pagerank_step(r2, src, dst, inv_deg)[0]
        d12 = float(jnp.sum(jnp.abs(r2 - r1)))
        d23 = float(jnp.sum(jnp.abs(r3 - r2)))
        assert d23 < d12


class TestAot:
    def test_artifact_registry_shapes(self):
        names = [a[0] for a in aot.artifacts()]
        assert names == ["trace_latency", "pagerank_step", "gups_chunk"]

    @pytest.mark.parametrize("name", ["trace_latency", "pagerank_step", "gups_chunk"])
    def test_lowering_produces_hlo_text(self, name):
        entry = next(a for a in aot.artifacts() if a[0] == name)
        _, fn, example = entry
        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:80]
        assert "ROOT" in text
