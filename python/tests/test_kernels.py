"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes and inputs; fixed seeds keep CI deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bank_scan as bs
from compile.kernels import gather_update as gu
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

LAT = dict(lat_hit=5, lat_miss=28, lat_conflict=49)


def rand_trace(rng, n, banks=bs.NUM_BANKS, rows=128):
    bank = rng.integers(0, banks, n).astype(np.int32)
    row = rng.integers(0, rows, n).astype(np.int32)
    return jnp.asarray(bank), jnp.asarray(row)


class TestBankScan:
    def test_known_sequence(self):
        bank = jnp.array([0, 0, 1, 0], jnp.int32)
        row = jnp.array([3, 3, 5, 4], jnp.int32)
        out = bs.bank_scan(bank, row, **LAT, block=4)
        assert out.tolist() == [28, 5, 28, 49]

    def test_matches_ref_random(self):
        rng = np.random.default_rng(0)
        bank, row = rand_trace(rng, 4096)
        got = bs.bank_scan(bank, row, **LAT)
        want = ref.bank_scan_ref(bank, row, **LAT)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_state_carries_across_blocks(self):
        # Same (bank,row) in consecutive blocks must be a hit in block 2.
        n = 2 * bs.BLOCK
        bank = jnp.zeros((n,), jnp.int32)
        row = jnp.zeros((n,), jnp.int32)
        out = bs.bank_scan(bank, row, **LAT)
        assert int(out[0]) == LAT["lat_miss"]
        assert int(out[bs.BLOCK]) == LAT["lat_hit"], "carry lost at block edge"

    def test_twin_pair_forces_conflict(self):
        # The twin-load property: same bank, row differing in the MSB.
        msb = 1 << 10
        bank = jnp.array([3, 3], jnp.int32)
        row = jnp.array([7, 7 ^ msb], jnp.int32)
        out = bs.bank_scan(bank, row, **LAT, block=2)
        assert out.tolist() == [LAT["lat_miss"], LAT["lat_conflict"]]

    @settings(max_examples=20, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        block=st.sampled_from([8, 64, 256]),
        rows=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, n_blocks, block, rows, seed):
        rng = np.random.default_rng(seed)
        bank, row = rand_trace(rng, n_blocks * block, rows=rows)
        got = bs.bank_scan(bank, row, **LAT, block=block)
        want = ref.bank_scan_ref(bank, row, **LAT)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_rejects_ragged_input(self):
        bank = jnp.zeros((100,), jnp.int32)
        with pytest.raises(AssertionError):
            bs.bank_scan(bank, bank, **LAT, block=64)


class TestGatherContrib:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        n, e = 64, 512
        src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        ranks = jnp.asarray(rng.random(n).astype(np.float32))
        inv_deg = jnp.asarray((1.0 / (1 + rng.integers(1, 8, n))).astype(np.float32))
        got = gu.gather_contrib(src, ranks, inv_deg)
        want = ref.gather_contrib_ref(src, ranks, inv_deg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.sampled_from([16, 128, 1024]),
        blocks=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, n, blocks, seed):
        rng = np.random.default_rng(seed)
        e = blocks * 128
        src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        ranks = jnp.asarray(rng.random(n).astype(np.float32))
        inv_deg = jnp.asarray(rng.random(n).astype(np.float32))
        got = gu.gather_contrib(src, ranks, inv_deg, block=128)
        want = ref.gather_contrib_ref(src, ranks, inv_deg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestGupsUpdate:
    def test_matches_ref_with_collisions(self):
        rng = np.random.default_rng(2)
        m, k = 256, 512  # k > m: guaranteed collisions
        table = jnp.asarray(rng.random(m).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, m, k).astype(np.int32))
        val = jnp.asarray(rng.random(k).astype(np.float32))
        got = gu.gups_update(table, idx, val)
        want = ref.gups_update_ref(table, idx, val)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_no_updates_is_identity_plus_zero(self):
        table = jnp.arange(16, dtype=jnp.float32)
        idx = jnp.zeros((4,), jnp.int32)
        val = jnp.zeros((4,), jnp.float32)
        got = gu.gups_update(table, idx, val)
        np.testing.assert_allclose(np.asarray(got), np.asarray(table))

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([32, 128]),
        k=st.sampled_from([16, 64, 256]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_collision_safety(self, m, k, seed):
        rng = np.random.default_rng(seed)
        table = jnp.asarray(rng.random(m).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, m, k).astype(np.int32))
        val = jnp.asarray(rng.random(k).astype(np.float32))
        got = gu.gups_update(table, idx, val)
        want = ref.gups_update_ref(table, idx, val)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
